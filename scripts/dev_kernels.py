"""Dev-loop: validate every Pallas kernel (interpret=True) vs the ref oracle."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.chi2_feedback import chi2_feedback
from repro.kernels.flash_attention import flash_attention
from repro.kernels.l1_distance import l1_distance
from repro.kernels.merge_attention import merge_attention

rng = np.random.default_rng(0)

# flash attention
for (B, H, KV, Sq, Sk, hd), causal, window, softcap in [
    ((1, 4, 2, 128, 128, 64), True, None, None),
    ((2, 4, 4, 64, 64, 32), True, None, 50.0),
    ((1, 2, 1, 100, 100, 80), True, 32, None),
    ((1, 2, 2, 64, 192, 128), False, None, None),
    ((2, 8, 2, 1, 256, 64), True, None, None),  # decode-style
]:
    q = jnp.asarray(rng.normal(size=(B, H, Sq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KV, Sk, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KV, Sk, hd)), jnp.float32)
    q_pos0 = Sk - Sq if Sq < Sk else 0
    got = flash_attention(q, k, v, causal=causal, window=window, softcap=softcap,
                          q_pos0=q_pos0, block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window, softcap=softcap, q_pos0=q_pos0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    print(f"[OK] flash B{B} H{H} KV{KV} Sq{Sq} Sk{Sk} hd{hd} causal={causal} win={window} cap={softcap}")

# l1 distance
for N, C in [(1000, 3), (65536, 2), (70000, 5), (128, 1)]:
    u = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    cen = jnp.asarray(rng.normal(size=(C, N)), jnp.float32)
    got = l1_distance(u, cen, block_n=4096, interpret=True)
    np.testing.assert_allclose(got, ref.l1_distance_ref(u, cen), rtol=1e-4)
    print(f"[OK] l1_distance N={N} C={C}")

# merge attention
for N in [100, 4096, 70000]:
    vm = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    va = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    vt = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    got = merge_attention(vm, va, vt, block_n=4096, interpret=True)
    want, _ = ref.merge_attention_ref(vm, va, vt)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    print(f"[OK] merge_attention N={N}")

# chi2 feedback
for M, J in [(1, 10), (7, 6), (300, 9)]:
    fp = jnp.asarray(np.abs(rng.normal(size=(M, J))) + 0.1, jnp.float32)
    ft = jnp.asarray(np.abs(rng.normal(size=(M, J))) + 0.1, jnp.float32)
    ss = jnp.asarray(jax.nn.softmax(jnp.asarray(rng.normal(size=(M, J)))), jnp.float32)
    got = chi2_feedback(fp, ft, ss, block_m=64, interpret=True)
    np.testing.assert_allclose(got, ref.chi2_feedback_ref(fp, ft, ss), rtol=1e-4)
    print(f"[OK] chi2_feedback M={M} J={J}")
print("all kernels validated")
