#!/usr/bin/env bash
# Tier-1 CI: the default (fast) suite plus the kernel-parity sweeps under
# both kernel backends and both server storage backends. No cache provider
# so repeated container runs never trip over a stale .pytest_cache.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "=== tier-1 (default backends: REPRO_KERNELS=auto, REPRO_PLANE=plane) ==="
python -m pytest -q -p no:cacheprovider -m "not slow"

PARITY_TESTS=(tests/test_batched_kernels.py tests/test_kernels.py tests/test_parameter_plane.py)

echo "=== kernel parity under REPRO_KERNELS=ref ==="
REPRO_KERNELS=ref python -m pytest -q -p no:cacheprovider "${PARITY_TESTS[@]}"

echo "=== kernel parity under REPRO_KERNELS=pallas (interpret on CPU) ==="
REPRO_KERNELS=pallas python -m pytest -q -p no:cacheprovider "${PARITY_TESTS[@]}"

echo "=== server/clustering on the pytree storage backend (REPRO_PLANE=pytree) ==="
REPRO_PLANE=pytree python -m pytest -q -p no:cacheprovider -m "not slow" \
    tests/test_parameter_plane.py tests/test_clustering.py tests/test_server_integration.py

echo "ci.sh: all green"
