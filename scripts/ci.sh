#!/usr/bin/env bash
# Tier-1 CI: the default (fast) suite plus the kernel-parity sweeps under
# both kernel backends and both server storage backends. No cache provider
# so repeated container runs never trip over a stale .pytest_cache.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "=== tier-1 (default backends: REPRO_KERNELS=auto, REPRO_PLANE=plane) ==="
python -m pytest -q -p no:cacheprovider -m "not slow"

PARITY_TESTS=(tests/test_batched_kernels.py tests/test_kernels.py tests/test_parameter_plane.py tests/test_async_coalesce.py)

echo "=== kernel parity under REPRO_KERNELS=ref ==="
REPRO_KERNELS=ref python -m pytest -q -p no:cacheprovider "${PARITY_TESTS[@]}"

echo "=== kernel parity under REPRO_KERNELS=pallas (interpret on CPU) ==="
REPRO_KERNELS=pallas python -m pytest -q -p no:cacheprovider "${PARITY_TESTS[@]}"

echo "=== server/clustering on the pytree storage backend (REPRO_PLANE=pytree) ==="
REPRO_PLANE=pytree python -m pytest -q -p no:cacheprovider -m "not slow" \
    tests/test_parameter_plane.py tests/test_clustering.py tests/test_server_integration.py

echo "=== loop client backend parity (REPRO_CLIENT=loop) ==="
# The fleet engine is the default since this CI soaked it; the seed
# per-client loop stays as the parity leg: tier-1's simulator-exercising
# suites with every Simulator on per-client dispatches (loop-vs-fleet
# parity is additionally asserted inside test_client_fleet.py itself).
REPRO_CLIENT=loop python -m pytest -q -p no:cacheprovider -m "not slow" \
    tests/test_client_fleet.py tests/test_server_integration.py tests/test_async_coalesce.py

echo "=== coalesced suite with predictor batching off (REPRO_PREDICTOR_BATCH=0) ==="
# Serial parity arm: the per-upload RNN learn/decide dispatches stay the
# reference trajectory the fused predictor-chain launch must match bitwise
# (the batching-on arm runs in tier-1 and the parity sweeps above).
REPRO_PREDICTOR_BATCH=0 python -m pytest -q -p no:cacheprovider \
    tests/test_async_coalesce.py tests/test_broadcast.py

echo "=== sharded plane over 8 simulated devices (REPRO_PLANE_MESH=auto) ==="
# Forced host-platform device count: the plane/kernel parity suites run with
# every DynamicClustering defaulting to the row-sharded backend (MIN_ROWS=0
# drives the sharded kernel dispatch even at test-sized fleets), plus the
# sharded-plane suite itself (skipped on the 1-device legs above).
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
REPRO_PLANE_MESH=auto REPRO_PLANE_MESH_MIN_ROWS=0 \
python -m pytest -q -p no:cacheprovider -m "not slow" \
    tests/test_sharded_plane.py tests/test_parameter_plane.py \
    tests/test_batched_kernels.py tests/test_clustering.py

echo "=== coalesced async + fleet mesh over 8 simulated devices ==="
# Event-coalesced loop as the ambient default (REPRO_ASYNC_COALESCE=45)
# with BOTH planes mesh-backed: the server plane row-sharded and the
# client fleet's model plane + data tensors sharded over the same 8
# virtual devices (REPRO_FLEET_MESH engages where the fleet size divides
# the shards). The parity suites assert the coalesced trajectories and
# loop/fleet agreement under this stack.
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
REPRO_PLANE_MESH=auto REPRO_PLANE_MESH_MIN_ROWS=0 \
REPRO_FLEET_MESH=auto REPRO_ASYNC_COALESCE=45 \
python -m pytest -q -p no:cacheprovider -m "not slow" \
    tests/test_async_coalesce.py tests/test_client_fleet.py

echo "=== model-axis plane compute over a 4x2 (plane, model) mesh ==="
# True dim-axis compute: pairwise-L1 psums per-shard partials, assign
# blends run elementwise per dim chunk, chi2 recruits the model axis for
# rows. The suite pins decision-identity + bitwise centers vs the
# single-device run (subprocess trajectory harness) and covers the
# REPRO_PLANE_MODEL_COMPUTE=off parity arm itself.
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
REPRO_PLANE_MESH=4x2 REPRO_PLANE_MESH_MIN_ROWS=0 \
python -m pytest -q -p no:cacheprovider -m "not slow" \
    tests/test_model_axis_plane.py

echo "=== compressed uplinks as the ambient default (REPRO_UPLINK=topk) ==="
# Tier-1's simulator-exercising suites with every uplink crossing the wire
# EF-top-k compressed: exact payload billing, codec checkpoint riding the
# server state, and the loop/fleet + coalesced/per-event compressed parity
# asserted inside test_uplink.py itself.
REPRO_UPLINK=topk python -m pytest -q -p no:cacheprovider -m "not slow" \
    tests/test_uplink.py tests/test_server_integration.py tests/test_client_fleet.py

echo "=== compressed coalesced-vs-per-event parity under both kernel backends ==="
# The REPRO_UPLINK parity suite (degenerate-window bitwise, real-window
# billing/trajectory agreement) must hold whichever kernel backend computes
# the training launches the codec consumes.
REPRO_KERNELS=ref python -m pytest -q -p no:cacheprovider tests/test_uplink.py
REPRO_KERNELS=pallas python -m pytest -q -p no:cacheprovider tests/test_uplink.py

echo "=== seeded chaos: REPRO_FAULTS=1 under both kernel backends ==="
# Deterministic fault injection over the resilience suite: crash/rejoin,
# death + plane-row reclamation, retry billing exactness, dup/reorder
# fences, drop-straggler policy, and mid-run server kill+restore. The env
# knobs make the ambient default chaotic so the knob-parsing path is the
# one under test; explicit FaultConfigs inside the suite pin the seeds.
REPRO_FAULTS=1 REPRO_FAULT_SEED=7 \
REPRO_KERNELS=ref python -m pytest -q -p no:cacheprovider tests/test_faults.py
REPRO_FAULTS=1 REPRO_FAULT_SEED=7 \
REPRO_KERNELS=pallas python -m pytest -q -p no:cacheprovider tests/test_faults.py

echo "=== faults-off bitwise identity (clean protocol untouched) ==="
# With REPRO_FAULTS unset no injector is constructed; the coalescing
# parity suite's bitwise trajectory pins (degenerate-window identity,
# byte accounting) double as the proof that the fault layer's hooks are
# inert when disabled. test_checkpoint.py covers the crash-safe
# staging rewrite the kill+restore path depends on.
python -m pytest -q -p no:cacheprovider \
    tests/test_async_coalesce.py tests/test_checkpoint.py

echo "=== poison chaos + ingest guard: REPRO_GUARD=on under both kernel backends ==="
# Value-level poison (NaN/scale/sign on the post-codec payload) with the
# guard engaged, as the ambient default so the knob-parsing path is the
# one under test: accept/reject against the MAD bounds, quarantine and
# eviction escalation, snapshot-ring center rollback, and the
# per-event/coalesced + loop/fleet schedule agreement. Explicit configs
# inside the suite pin the seeds and the negative control.
REPRO_FAULTS=1 REPRO_FAULT_SEED=7 \
REPRO_FAULT_POISON_NAN=0.08 REPRO_FAULT_POISON_SCALE=0.06 REPRO_FAULT_POISON_SIGN=0.06 \
REPRO_GUARD=on \
REPRO_KERNELS=ref python -m pytest -q -p no:cacheprovider tests/test_guard.py
REPRO_FAULTS=1 REPRO_FAULT_SEED=7 \
REPRO_FAULT_POISON_NAN=0.08 REPRO_FAULT_POISON_SCALE=0.06 REPRO_FAULT_POISON_SIGN=0.06 \
REPRO_GUARD=on \
REPRO_KERNELS=pallas python -m pytest -q -p no:cacheprovider tests/test_guard.py

echo "=== guard-off bitwise identity (unguarded ingest untouched) ==="
# With REPRO_GUARD unset no guard is constructed, ingest_chain compiles
# without stats, and no snapshot rings are allocated; the guard suite's
# clean-identity tests pin that a guard-on clean run matches this leg's
# trajectories bitwise, and the rest of the matrix (all guard-off) is
# itself the regression that the hooks are inert.
python -m pytest -q -p no:cacheprovider tests/test_guard.py

echo "=== REPRO_TASK=lm smoke (LoRA/head deltas over the frozen tiny_lm base) ==="
# The LM personalization workload end-to-end on both simulator loops:
# run_sync (fedavg) + coalesced run_async (echopfl), loop/fleet backend
# agreement, delta-only payload billing, and the kernel-vs-model-layer
# attention oracle cross-check the LM training path leans on.
REPRO_TASK=lm python -m pytest -q -p no:cacheprovider -m "not slow" \
    tests/test_lm_task.py tests/test_flash_vs_layers_reference.py

echo "ci.sh: all green"
