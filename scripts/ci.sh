#!/usr/bin/env bash
# Tier-1 CI: the default (fast) suite plus the kernel-parity sweeps under
# both kernel backends and both server storage backends. No cache provider
# so repeated container runs never trip over a stale .pytest_cache.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "=== tier-1 (default backends: REPRO_KERNELS=auto, REPRO_PLANE=plane) ==="
python -m pytest -q -p no:cacheprovider -m "not slow"

PARITY_TESTS=(tests/test_batched_kernels.py tests/test_kernels.py tests/test_parameter_plane.py)

echo "=== kernel parity under REPRO_KERNELS=ref ==="
REPRO_KERNELS=ref python -m pytest -q -p no:cacheprovider "${PARITY_TESTS[@]}"

echo "=== kernel parity under REPRO_KERNELS=pallas (interpret on CPU) ==="
REPRO_KERNELS=pallas python -m pytest -q -p no:cacheprovider "${PARITY_TESTS[@]}"

echo "=== server/clustering on the pytree storage backend (REPRO_PLANE=pytree) ==="
REPRO_PLANE=pytree python -m pytest -q -p no:cacheprovider -m "not slow" \
    tests/test_parameter_plane.py tests/test_clustering.py tests/test_server_integration.py

echo "=== batched client plane (REPRO_CLIENT=fleet) ==="
# Tier-1's simulator-exercising suites with every Simulator defaulting to
# the vectorized client-fleet engine (the remaining tier-1 files never
# construct a Simulator, so REPRO_CLIENT cannot affect them; loop-vs-fleet
# parity is additionally asserted inside test_client_fleet.py itself).
REPRO_CLIENT=fleet python -m pytest -q -p no:cacheprovider -m "not slow" \
    tests/test_client_fleet.py tests/test_server_integration.py

echo "=== sharded plane over 8 simulated devices (REPRO_PLANE_MESH=auto) ==="
# Forced host-platform device count: the plane/kernel parity suites run with
# every DynamicClustering defaulting to the row-sharded backend (MIN_ROWS=0
# drives the sharded kernel dispatch even at test-sized fleets), plus the
# sharded-plane suite itself (skipped on the 1-device legs above).
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
REPRO_PLANE_MESH=auto REPRO_PLANE_MESH_MIN_ROWS=0 \
python -m pytest -q -p no:cacheprovider -m "not slow" \
    tests/test_sharded_plane.py tests/test_parameter_plane.py \
    tests/test_batched_kernels.py tests/test_clustering.py

echo "ci.sh: all green"
