"""Dev-loop: run EchoPFL + all baselines on the image task, print summaries."""
import sys
import time

from repro.fl.experiment import run_experiment

strategies = sys.argv[1:] or ["echopfl", "fedavg", "fedasyn", "fedsea", "clusterfl", "oort", "standalone"]
for s in strategies:
    t0 = time.time()
    _, _, strat, report = run_experiment(
        "image_recognition", s, num_clients=12, max_time=2400.0, rounds=25, seed=1
    )
    wall = time.time() - t0
    print(f"{s:12s} final={report.final_acc:.3f} t2t={report.time_to_target} "
          f"up={report.up_bytes/1e6:.1f}MB down={report.down_bytes/1e6:.1f}MB "
          f"extra={ {k: v for k, v in report.extra.items() if k not in ('latent_clusters','task')} } "
          f"[wall {wall:.1f}s]")
